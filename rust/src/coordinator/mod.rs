//! L3 coordinator: the serving stack around the compiled generator.
//!
//! A shared bounded request queue ([`queue::LaneQueue`] — one
//! admission-controlled FIFO lane per model) feeds a pool of
//! `ServerConfig.workers` dispatcher threads. Each worker owns its own
//! compute backends — one executor per model lane, constructed *inside*
//! the worker thread from `Send + Sync` factories called once per worker
//! (PJRT handles are not `Send`; the native path shares ONE immutable
//! [`crate::engine::Program`] per model behind an `Arc` and gives every
//! worker its own `Scratch`). Each worker independently implements
//! *continuous batching*: block for the first request of any lane
//! (round-robin fair), fill a single-lane batch up to `max_batch` or
//! until the `batch_timeout` fill budget elapses — whichever fires first
//! ([`queue::LaneQueue::fill`]) — drop requests whose deadline already
//! expired BEFORE compute, pack the survivors' latents, run one
//! executable call, fan responses back out. Backpressure is the bounded
//! lane: [`Server::submit`] fails fast when full, and every such shed is
//! counted in [`Metrics`] so the network front door ([`crate::server`])
//! can answer it explicitly.
//!
//! Invariants (tested in rust/tests/coordinator.rs,
//! rust/tests/coordinator_stress.rs and rust/tests/front_door.rs, at any
//! worker count):
//! * every submitted request gets exactly one resolution (response,
//!   disconnect on batch failure, or expired-deadline disconnect counted
//!   in `Metrics.expired`) — no drop/dup, including requests already
//!   accepted when [`Server::shutdown`] is called (close-then-drain);
//! * responses carry the request's own image (order-independent identity);
//! * a batch only ever contains requests for ONE model lane;
//! * per-lane queue depth never exceeds `queue_cap`;
//! * batch sizes never exceed `max_batch`;
//! * a failed batch disconnects exactly its own requests' responders and
//!   the pool keeps serving subsequent batches.
//!
//! Failure domains (DESIGN.md §15, tested in rust/tests/chaos.rs):
//! * a panic inside an executing batch is CONTAINED: the dispatcher
//!   catches it, rebuilds the lane's executor, and bisect-retries the
//!   batch's requests individually — requests that pass are served
//!   normally, a request that panics the worker AGAIN is quarantined
//!   with a typed [`Response::fault`] (the poison pill gets a 500, the
//!   lane keeps serving everyone else);
//! * a panic anywhere else in the dispatch loop is caught by the
//!   in-thread supervisor, which rebuilds every executor and resumes —
//!   the pool always returns to `cfg.workers` strength
//!   (`Metrics.live_workers`), and every caught panic counts in
//!   `Metrics.worker_panics` + journals `WorkerPanic`/`WorkerRespawn`;
//! * with [`ServerConfig::breaker`] set, each lane has a circuit
//!   breaker: `threshold` consecutive batch failures open it (submits
//!   bounce fast with [`SubmitError::LaneDown`]) and a half-open probe
//!   closes it again ([`fault::Breaker`]);
//! * every recovery path above is driven deterministically by the
//!   seeded chaos plan ([`fault::FaultPlan`], `ServerConfig.chaos`).

pub mod executor;
pub mod fault;
pub mod metrics;
pub mod queue;
pub mod watchdog;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::{DeconvImpl, Precision, Program};
use crate::obs::journal::{EventKind, Journal, NO_LANE};
use crate::obs::{self, LayerStages, Span, StageSink};

pub use executor::{chunk_batches, plan_batch, BatchExecutor, NativeExecutor, PjrtExecutor};
pub use fault::{Breaker, BreakerConfig, BreakerState, ChaosAction, Fault, FaultKind, FaultPlan};
pub use metrics::{Metrics, MetricsSnapshot};
pub use queue::{BoundedQueue, LaneQueue, PopDeadline, PushError};
pub use watchdog::WatchdogConfig;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// maximum requests packed into one executable call
    pub max_batch: usize,
    /// the continuous batcher's fill budget: how long a worker waits to
    /// fill a batch after the first arrival (microsecond granularity —
    /// `Duration::from_micros`). The batch executes at `max_batch` OR
    /// when this budget elapses, whichever fires first.
    pub batch_timeout: Duration,
    /// bounded PER-LANE queue depth (admission-control limit): each
    /// model's lane holds at most this many queued requests, and a full
    /// lane sheds new submits without touching other models' lanes
    pub queue_cap: usize,
    /// which benchmark model the *native* backend serves (any spelling
    /// [`crate::networks::by_name`] accepts: dcgan, artgan, sngan, gpgan,
    /// mde, fst) — [`Server::start_native`] compiles it ONCE into an
    /// `engine::Program` shared by every worker. Multi-model servers
    /// ([`Server::start_native_multi`]) ignore this field and take the
    /// model list explicitly. The PJRT backend takes an explicit artifact
    /// prefix instead (artifact families can outnumber models, e.g.
    /// `dcgan_sd` vs `dcgan_nzp`); callers should derive it from
    /// [`crate::networks::slug`], as the CLI does.
    pub model: String,
    /// dispatcher threads draining the shared queue (clamped to >= 1).
    /// Each owns its own executor per model lane: its own `Scratch` on
    /// the native path, its own PJRT client on the artifact path.
    pub workers: usize,
    /// numeric precision of the *native* backend's compiled program
    /// ([`Precision::Int8`] = the quantized serving mode: int8 weights and
    /// activations, i32 accumulate, prepared once at compile time and
    /// shared across workers like any other program). The PJRT backend
    /// ignores this — its precision is baked into the artifacts.
    pub precision: Precision,
    /// record per-request trace spans (`{queue, batch_form, compute,
    /// respond}` — [`Response::span`]) and honor per-request stage-trace
    /// opt-ins ([`SubmitOpts::trace_stages`]). On by default: the span
    /// costs two extra `Instant::now()` samples per *batch* plus one per
    /// request. `false` turns every span field into 0 and suppresses
    /// engine stage sinks entirely — the knob the serving bench's
    /// tracing-overhead gate compares against (DESIGN.md §12).
    pub record_spans: bool,
    /// the flight recorder (DESIGN.md §14): when set, the submit path
    /// and every dispatcher emit compact journal events (enqueue,
    /// batch-form, dispatch, compute, respond, shed, expire) that
    /// `/debug/trace` and `repro trace` export as a Perfetto timeline.
    /// `None` (the default) follows the zero-overhead contract: no
    /// journal ⇒ no event timestamps taken anywhere on the hot path.
    pub journal: Option<Arc<Journal>>,
    /// spawn the serving watchdog ([`watchdog::WatchdogConfig`]) —
    /// requires `journal` (the watchdog scans it); ignored with a
    /// logged warning otherwise.
    pub watchdog: Option<WatchdogConfig>,
    /// seeded fault-injection plan (DESIGN.md §15): when set, each batch
    /// dispatch draws one chaos tick that may inject a worker panic, an
    /// executor error, or a slow-compute stall. `None` (the default) is
    /// production: no draws, no overhead. Containment retries NEVER draw
    /// chaos, so recovery is deterministic.
    pub chaos: Option<Arc<FaultPlan>>,
    /// per-lane circuit breakers ([`fault::Breaker`]): `threshold`
    /// consecutive batch failures open a lane (submits return
    /// [`SubmitError::LaneDown`] without touching the queue) until a
    /// half-open probe succeeds. `None` (the default) disables breakers
    /// and keeps the legacy fail-every-batch semantics.
    pub breaker: Option<BreakerConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 64,
            model: "dcgan".to_string(),
            workers: 1,
            precision: Precision::F32,
            record_spans: true,
            journal: None,
            watchdog: None,
            chaos: None,
            breaker: None,
        }
    }
}

/// A generation request: latent vector in, image out.
struct Request {
    id: u64,
    /// model lane index (0 on single-model servers)
    lane: usize,
    z: Vec<f32>,
    submitted: Instant,
    /// absolute completion deadline: a dispatcher drops the request
    /// WITHOUT computing it if this instant has passed when the batch
    /// forms (counted in `Metrics.expired`; the responder is disconnected
    /// so the submitter observes the drop immediately)
    deadline: Option<Instant>,
    /// trace id minted at admission (or caller-supplied, e.g. the front
    /// door's `X-Request-Id`); rides end to end into [`Response::span`]
    trace_id: u64,
    /// caller opted into the per-layer engine stage breakdown
    /// (`X-Trace: 1` at the front door) — the dispatcher attaches a
    /// [`StageSink`] to this request's batch
    traced: bool,
    resp: mpsc::Sender<Response>,
}

/// Per-request submit options beyond the latent itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// absolute completion deadline (see [`Server::submit_to`])
    pub deadline: Option<Instant>,
    /// caller-supplied trace id; a fresh one is minted when `None`
    pub trace_id: Option<u64>,
    /// request the per-layer engine stage breakdown for this request's
    /// batch ([`Response::stages`]); requires `ServerConfig.record_spans`
    pub trace_stages: bool,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub image: Vec<f32>,
    /// time spent waiting in queue + batcher (total latency minus the
    /// batch's compute time)
    pub queue_us: u64,
    /// executable wall time for the whole batch
    pub compute_us: u64,
    /// how many requests shared the executable call
    pub batch_size: usize,
    /// where this request's wall time went (all-zero when
    /// `ServerConfig.record_spans` is off). Unlike the coarse
    /// [`Response::queue_us`] (total minus compute, kept for
    /// compatibility), the span separates pure lane-queue wait from the
    /// continuous batcher's fill window and the response fan-out.
    pub span: Span,
    /// per-layer engine stage breakdown — only `Some` when this request
    /// asked for it ([`SubmitOpts::trace_stages`]) and the backend
    /// supports stage attribution (the native engine does). Timings cover
    /// the whole batch the request rode in (one engine pass serves the
    /// batch), shared behind an `Arc` by every traced request of that
    /// batch.
    pub stages: Option<Arc<Vec<LayerStages>>>,
    /// `Some` when this request terminated with a typed fault instead of
    /// an image (`image` is empty then): the batch panicked the worker
    /// and the request's containment retry also failed, or the request
    /// was quarantined as a poison pill. The responder channel still
    /// fires — panic containment means no stranded receivers.
    pub fault: Option<Fault>,
}

/// Why a submit was refused. `Full` is the admission-control shed signal
/// (already counted in [`Metrics`] when this is returned); the caller owes
/// the client an explicit answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// the model's lane is at `queue_cap` (backpressure shed)
    Full,
    /// the server is shutting down (or already stopped)
    Closed,
    /// no such model lane
    UnknownModel,
    /// the lane's circuit breaker is open (recent consecutive batch
    /// failures); counted in `Metrics.lane_down`, retry after a cooldown
    LaneDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server stopped"),
            SubmitError::UnknownModel => write!(f, "unknown model lane"),
            SubmitError::LaneDown => write!(f, "lane down (circuit breaker open)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// One model lane of a multi-tenant server: a display name plus the
/// per-worker executor factory (the factory runs once inside EACH
/// dispatcher thread, receiving the worker index).
pub struct ModelLane {
    pub name: String,
    pub factory: Box<dyn Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync>,
}

impl ModelLane {
    /// A lane over an already-compiled shared program: every worker gets
    /// its own [`NativeExecutor`] (private `Scratch`) over the ONE
    /// `Arc<Program>`.
    pub fn native(name: impl Into<String>, program: Arc<Program>) -> ModelLane {
        ModelLane {
            name: name.into(),
            factory: Box::new(move |_worker| {
                let exec = NativeExecutor::from_program(program.clone());
                Ok(Box::new(exec) as Box<dyn BatchExecutor>)
            }),
        }
    }
}

/// Handle to a running coordinator.
pub struct Server {
    queue: Arc<LaneQueue<Request>>,
    models: Vec<String>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    cfg: Arc<ServerConfig>,
    /// raised before joining so the watchdog thread (in `handles` like
    /// the dispatchers) exits promptly
    watchdog_stop: Arc<AtomicBool>,
    /// per-lane circuit breakers, `None` unless `cfg.breaker` is set
    /// (shared with every dispatcher, which records batch outcomes)
    breakers: Option<Arc<Vec<Breaker>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Server {
    /// Start a single-model worker pool with a backend factory. The
    /// factory runs once *inside each* dispatcher thread (`cfg.workers`
    /// times, receiving the worker index); startup fails if any worker's
    /// backend fails to construct.
    pub fn start_with<F, E>(cfg: ServerConfig, factory: F) -> Result<Server>
    where
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
        E: BatchExecutor + 'static,
    {
        let name = cfg.model.clone();
        Self::start_multi_with(
            cfg,
            vec![ModelLane {
                name,
                factory: Box::new(move |worker| {
                    factory(worker).map(|e| Box::new(e) as Box<dyn BatchExecutor>)
                }),
            }],
        )
    }

    /// Start a multi-tenant worker pool: ONE shared queue with one
    /// admission-controlled lane per model, `cfg.workers` dispatcher
    /// threads each holding one executor per lane. Every batch contains
    /// requests of exactly one lane; workers take work from any lane
    /// (round-robin fair).
    pub fn start_multi_with(cfg: ServerConfig, lanes: Vec<ModelLane>) -> Result<Server> {
        if lanes.is_empty() {
            return Err(anyhow!("a server needs at least one model lane"));
        }
        let workers = cfg.workers.max(1);
        let queue = Arc::new(LaneQueue::new(lanes.len(), cfg.queue_cap));
        let metrics = Arc::new(Metrics::with_lanes(workers, lanes.len()));
        let models: Vec<String> = lanes.iter().map(|l| l.name.clone()).collect();
        let breakers: Option<Arc<Vec<Breaker>>> = cfg
            .breaker
            .map(|bc| Arc::new((0..lanes.len()).map(|_| Breaker::new(bc)).collect()));
        let lanes = Arc::new(lanes);
        let cfg = Arc::new(cfg);
        // report backend construction success/failure synchronously
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue2 = queue.clone();
            let metrics2 = metrics.clone();
            let lanes2 = lanes.clone();
            let cfg2 = cfg.clone();
            let breakers2 = breakers.clone();
            let ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("sd-dispatcher-{w}"))
                .spawn(move || {
                    let mut execs: Vec<Box<dyn BatchExecutor>> = Vec::new();
                    for lane in lanes2.iter() {
                        match (lane.factory)(w) {
                            Ok(e) => execs.push(e),
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        }
                    }
                    let _ = ready.send(Ok(()));
                    metrics2.inc_live_workers();
                    // In-thread supervisor: the dispatch loop's own panic
                    // containment handles executor panics, but if the loop
                    // itself ever panics (a bug in dispatch bookkeeping,
                    // say), the supervisor catches it, rebuilds every
                    // executor, and resumes — the pool NEVER silently
                    // shrinks below `cfg.workers` (DESIGN.md §15).
                    loop {
                        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            dispatch_loop(
                                w,
                                &queue2,
                                &mut execs,
                                &lanes2,
                                &cfg2,
                                &metrics2,
                                breakers2.as_deref().map(|v| v.as_slice()),
                            );
                        }));
                        match run {
                            Ok(()) => break, // queue closed and drained
                            Err(payload) => {
                                metrics2.record_worker_panic();
                                if let Some(j) = &cfg2.journal {
                                    j.emit(EventKind::WorkerPanic, NO_LANE, 2, 0, 0);
                                }
                                obs::log::error(
                                    "coordinator",
                                    "dispatch loop panicked; supervisor respawning worker",
                                    &[
                                        ("worker", w.to_string()),
                                        ("panic", panic_message(payload.as_ref())),
                                    ],
                                );
                                // best-effort executor rebuild: a factory
                                // failure keeps the old executor rather
                                // than killing the worker
                                for (i, lane) in lanes2.iter().enumerate() {
                                    match (lane.factory)(w) {
                                        Ok(e) => execs[i] = e,
                                        Err(e) => obs::log::error(
                                            "coordinator",
                                            &format!("executor rebuild failed: {e:#}"),
                                            &[
                                                ("worker", w.to_string()),
                                                ("lane", i.to_string()),
                                            ],
                                        ),
                                    }
                                }
                                if let Some(j) = &cfg2.journal {
                                    j.emit(EventKind::WorkerRespawn, NO_LANE, 0, 0, 0);
                                }
                            }
                        }
                    }
                    metrics2.dec_live_workers();
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);
        for _ in 0..workers {
            let failed = match ready_rx.recv() {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(e),
                Err(_) => Some(anyhow!("dispatcher died during startup")),
            };
            if let Some(e) = failed {
                queue.close();
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
        let watchdog_stop = Arc::new(AtomicBool::new(false));
        match (&cfg.watchdog, &cfg.journal) {
            (Some(wcfg), Some(journal)) => {
                let wcfg = *wcfg;
                let journal = journal.clone();
                let queue2 = queue.clone();
                let metrics2 = metrics.clone();
                let stop = watchdog_stop.clone();
                let spawned = std::thread::Builder::new()
                    .name("sd-watchdog".to_string())
                    .spawn(move || watchdog::run(&queue2, &metrics2, &journal, wcfg, &stop));
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        queue.close();
                        for h in handles {
                            let _ = h.join();
                        }
                        return Err(e.into());
                    }
                }
            }
            (Some(_), None) => {
                obs::log::warn(
                    "coordinator",
                    "watchdog configured without a journal — not started",
                    &[],
                );
            }
            _ => {}
        }
        Ok(Server {
            queue,
            models,
            next_id: AtomicU64::new(0),
            metrics,
            cfg,
            watchdog_stop,
            breakers,
            handles: Mutex::new(handles),
        })
    }

    /// Start the production PJRT server for a model artifact prefix. Every
    /// worker constructs its own engine inside its thread (PJRT handles
    /// are not `Send`).
    pub fn start_pjrt(
        cfg: ServerConfig,
        artifact_dir: std::path::PathBuf,
        prefix: String,
    ) -> Result<Server> {
        Self::start_with(cfg, move |_worker| {
            PjrtExecutor::new(artifact_dir.clone(), &prefix)
        })
    }

    /// Start a server over the CPU-native engine executor: the generator
    /// selected by `cfg.model` is compiled ONCE into an immutable
    /// `engine::Program` (SD filters pre-split and packed at compile time,
    /// at `cfg.precision` — int8 constants and calibration included) and
    /// shared by all `cfg.workers` workers via `Arc` — each worker
    /// gets its own `Scratch`. Works from a fresh checkout (no artifacts
    /// needed); all six benchmark networks route here.
    pub fn start_native(cfg: ServerConfig, weight_seed: u64) -> Result<Server> {
        let net = crate::networks::by_name_or_err(&cfg.model)?;
        let program = Arc::new(Program::from_seed_prec(
            &net,
            DeconvImpl::Sd,
            weight_seed,
            cfg.precision,
        )?);
        Self::start_native_program(cfg, program)
    }

    /// [`Server::start_native`] over an already-compiled (possibly shared,
    /// possibly custom) program — one compile, N workers.
    pub fn start_native_program(cfg: ServerConfig, program: Arc<Program>) -> Result<Server> {
        let name = cfg.model.clone();
        Self::start_multi_with(cfg, vec![ModelLane::native(name, program)])
    }

    /// Start a multi-tenant native server: one `Arc<Program>` per model,
    /// ONE worker pool serving every lane — the all-six-models-from-one-
    /// process shape the network front door ([`crate::server`]) exposes.
    pub fn start_native_multi(
        cfg: ServerConfig,
        programs: Vec<(String, Arc<Program>)>,
    ) -> Result<Server> {
        let lanes = programs
            .into_iter()
            .map(|(name, p)| ModelLane::native(name, p))
            .collect();
        Self::start_multi_with(cfg, lanes)
    }

    /// The model lane names, in lane order.
    pub fn models(&self) -> &[String] {
        &self.models
    }

    /// Resolve a model name to its lane index (case-insensitive).
    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.eq_ignore_ascii_case(name))
    }

    /// Submit a latent vector to model lane `lane` with an optional
    /// completion deadline. Returns a receiver for the response, or a
    /// typed error immediately: [`SubmitError::Full`] is the
    /// admission-control shed (counted in [`Metrics`] before returning —
    /// the caller owes the client an explicit shed answer, never a silent
    /// drop). A request whose deadline passes before its batch forms is
    /// dropped WITHOUT compute: its responder disconnects and
    /// `Metrics.expired` counts it.
    pub fn submit_to(
        &self,
        lane: usize,
        z: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Response>, SubmitError> {
        self.submit_opts(lane, z, SubmitOpts { deadline, ..SubmitOpts::default() })
    }

    /// [`Server::submit_to`] with the full per-request options: deadline,
    /// caller-supplied trace id, and the per-layer stage-trace opt-in.
    pub fn submit_opts(
        &self,
        lane: usize,
        z: Vec<f32>,
        opts: SubmitOpts,
    ) -> Result<Receiver<Response>, SubmitError> {
        if lane >= self.models.len() {
            return Err(SubmitError::UnknownModel);
        }
        if let Some(bs) = &self.breakers {
            if !bs[lane].admit(Instant::now()) {
                self.metrics.record_lane_down();
                return Err(SubmitError::LaneDown);
            }
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        let trace_id = opts.trace_id.unwrap_or_else(obs::trace::mint_trace_id);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            lane,
            z,
            submitted: Instant::now(),
            deadline: opts.deadline,
            trace_id,
            traced: opts.trace_stages,
            resp: resp_tx,
        };
        match self.queue.try_push(lane, req) {
            Ok(depth) => {
                self.metrics.note_queue_depth(depth);
                self.metrics.inc_in_flight();
                if let Some(j) = &self.cfg.journal {
                    j.emit(EventKind::Enqueue, lane as u16, 0, depth as u64, trace_id);
                }
                Ok(resp_rx)
            }
            Err(PushError::Full(_)) => {
                self.metrics.record_shed(lane);
                if let Some(j) = &self.cfg.journal {
                    j.emit(EventKind::Shed, lane as u16, 0, 0, trace_id);
                }
                Err(SubmitError::Full)
            }
            Err(PushError::Closed(_)) => Err(SubmitError::Closed),
        }
    }

    /// Submit a latent vector to lane 0. Returns a receiver for the
    /// response, or an error immediately if the queue is full
    /// (backpressure, counted as a shed) or closed.
    pub fn submit(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        self.submit_to(0, z, None).map_err(|e| anyhow!("{e}"))
    }

    /// Submit to lane 0, blocking while the queue is full. An open
    /// circuit breaker still refuses fast — blocking admission must not
    /// pile requests onto a lane that is known to be failing.
    pub fn submit_blocking(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        if let Some(bs) = &self.breakers {
            if !bs[0].admit(Instant::now()) {
                self.metrics.record_lane_down();
                return Err(anyhow!("{}", SubmitError::LaneDown));
            }
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        let trace_id = obs::trace::mint_trace_id();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            lane: 0,
            z,
            submitted: Instant::now(),
            deadline: None,
            trace_id,
            traced: false,
            resp: resp_tx,
        };
        match self.queue.push(0, req) {
            Ok(depth) => {
                self.metrics.note_queue_depth(depth);
                self.metrics.inc_in_flight();
                if let Some(j) = &self.cfg.journal {
                    j.emit(EventKind::Enqueue, 0, 0, depth as u64, trace_id);
                }
                Ok(resp_rx)
            }
            Err(_) => Err(anyhow!("server stopped")),
        }
    }

    /// Metrics snapshot with the live per-lane queue depths filled in
    /// (the raw `Metrics` sink cannot see the queue).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        s.lane_depth = (0..self.queue.lane_count())
            .map(|l| self.queue.len(l) as u64)
            .collect();
        s
    }

    /// The flight recorder, when one was configured.
    pub fn journal(&self) -> Option<&Arc<Journal>> {
        self.cfg.journal.as_ref()
    }

    /// Per-lane circuit-breaker states (lane order matches
    /// [`Server::models`]); `None` when breakers are not configured.
    pub fn breaker_states(&self) -> Option<Vec<BreakerState>> {
        self.breakers
            .as_ref()
            .map(|bs| bs.iter().map(|b| b.state()).collect())
    }

    /// The configuration this server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Stop accepting new requests, then wait for the workers to drain the
    /// queue: every already-accepted request still gets its response
    /// (close-then-drain). Idempotent, and callable from any thread while
    /// others still hold `&Server` (mid-flight shutdown is exercised in
    /// rust/tests/coordinator_stress.rs and, over TCP, in
    /// rust/tests/front_door.rs).
    pub fn shutdown(&self) {
        self.queue.close();
        self.watchdog_stop.store(true, Ordering::Relaxed);
        // poison-recovering lock: shutdown must drain even after a panic
        // elsewhere poisoned the handle list (it is always a valid Vec)
        let handles: Vec<JoinHandle<()>> = std::mem::take(
            &mut *self
                .handles
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        self.watchdog_stop.store(true, Ordering::Relaxed);
        let handles = self
            .handles
            .get_mut()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One worker's dispatch loop: pop the first request of any lane
/// (blocking, round-robin fair), continuously fill a single-lane batch
/// until `max_batch` or the fill budget (whichever first), drop
/// expired-deadline requests BEFORE compute, execute INSIDE a panic
/// container ([`contained_execute`]), fan out. A panicking batch never
/// strands its receivers: the lane's executor is rebuilt and every
/// request of the batch is retried individually ([`retry_one`] — the
/// bisect step), quarantining repeat offenders with a typed fault.
/// Exits only when the queue is closed *and* drained, so accepted
/// requests are never dropped by shutdown.
fn dispatch_loop(
    worker: usize,
    queue: &LaneQueue<Request>,
    execs: &mut [Box<dyn BatchExecutor>],
    lanes: &[ModelLane],
    cfg: &ServerConfig,
    metrics: &Metrics,
    breakers: Option<&[Breaker]>,
) {
    let journal = cfg.journal.as_deref();
    loop {
        let (lane, first) = match queue.pop_any() {
            Some(x) => x,
            None => return, // closed and fully drained
        };
        // the journal shares record_spans' zero-overhead contract: both
        // knobs off ⇒ no Instant sample here (DESIGN.md §12/§14)
        let t_form = if cfg.record_spans || journal.is_some() {
            Some(Instant::now())
        } else {
            None
        };
        if let Some(j) = journal {
            j.emit(EventKind::BatchFormBegin, lane as u16, 0, 0, first.trace_id);
        }
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_timeout;
        queue.fill(lane, &mut batch, cfg.max_batch, deadline);

        // admission control half 2: drop requests whose own deadline has
        // already passed — BEFORE spending compute on them. Dropping the
        // responder disconnects the submitter immediately (the front door
        // answers 504); the count is visible in Metrics.expired.
        let now = Instant::now();
        let (live, expired): (Vec<Request>, Vec<Request>) =
            batch.into_iter().partition(|r| match r.deadline {
                Some(d) => d > now,
                None => true,
            });
        for r in &expired {
            metrics.record_expired(lane);
            metrics.dec_in_flight();
            if let Some(j) = journal {
                j.emit(EventKind::DeadlineExpire, lane as u16, 0, 0, r.trace_id);
            }
        }
        drop(expired);
        if live.is_empty() {
            continue;
        }

        // batch_form covers the continuous-batcher fill + expiry triage;
        // zero (and unsampled) when both record_spans and the journal
        // are off
        let batch_form_us = match t_form {
            Some(t) => t.elapsed().as_micros() as u64,
            None => 0,
        };
        if let Some(j) = journal {
            j.emit(
                EventKind::BatchFormEnd,
                lane as u16,
                live.len().min(u16::MAX as usize) as u16,
                batch_form_us,
                live[0].trace_id,
            );
        }
        let zs: Vec<Vec<f32>> = live.iter().map(|r| r.z.clone()).collect();
        // stage tracing is strictly opt-in per request AND gated on the
        // server-wide record_spans knob: a batch with no traced request
        // runs the exact untraced compute path (DESIGN.md §12)
        let want_stages = cfg.record_spans && live.iter().any(|r| r.traced);
        let mut sink = if want_stages { Some(StageSink::new()) } else { None };
        if let Some(j) = journal {
            j.emit(
                EventKind::Dispatch,
                lane as u16,
                live.len().min(u16::MAX as usize) as u16,
                0,
                live[0].trace_id,
            );
        }
        let t0 = Instant::now();
        let outcome = contained_execute(&mut execs[lane], &zs, sink.as_mut(), cfg.chaos.as_deref());
        match outcome {
            ExecOutcome::Ok(images) => {
                let t_done = Instant::now();
                let compute_us = (t_done - t0).as_micros() as u64;
                if let Some(bs) = breakers {
                    bs[lane].record_success();
                }
                metrics.record_batch(
                    worker,
                    lane,
                    live.len(),
                    compute_us,
                    batch_form_us + compute_us,
                );
                let stages: Option<Arc<Vec<LayerStages>>> = sink.map(|s| Arc::new(s.layers));
                if let Some(j) = journal {
                    j.emit(
                        EventKind::ComputeEnd,
                        lane as u16,
                        live.len().min(u16::MAX as usize) as u16,
                        compute_us,
                        0,
                    );
                    // one Stage event per nonzero (layer, stage) cell of
                    // the batch's sink — the exporter re-times them
                    // inside the compute slice
                    if let Some(rows) = &stages {
                        for (idx, row) in rows.iter().enumerate().take(1 << 14) {
                            let cells = [
                                (0u16, row.im2col_us),
                                (1, row.gemm_us),
                                (2, row.epilogue_us),
                                (3, row.interleave_us),
                            ];
                            for (code, us) in cells {
                                if us > 0 {
                                    let aux = ((idx as u16) << 2) | code;
                                    j.emit(EventKind::Stage, lane as u16, aux, us, 0);
                                }
                            }
                        }
                    }
                }
                for (req, image) in live.into_iter().zip(images) {
                    // sample elapsed() exactly once per request and derive
                    // queue time from it — re-sampling could attribute the
                    // batcher wait to neither bucket (regression-tested by
                    // coordinator::queue_time_accounts_for_batch_wait)
                    let total_us = req.submitted.elapsed().as_micros() as u64;
                    let queue_us = total_us.saturating_sub(compute_us);
                    let span = if cfg.record_spans {
                        // respond_us: fan-out time for requests served
                        // before this one in the same batch (grows down
                        // the loop); span.queue_us is the residual so the
                        // four stages sum to total_us exactly
                        let respond_us = t_done.elapsed().as_micros() as u64;
                        Span {
                            trace_id: req.trace_id,
                            queue_us: total_us
                                .saturating_sub(batch_form_us)
                                .saturating_sub(compute_us)
                                .saturating_sub(respond_us),
                            batch_form_us,
                            compute_us,
                            respond_us,
                        }
                    } else {
                        Span::default()
                    };
                    metrics.record_request_latency(total_us, queue_us, compute_us);
                    metrics.dec_in_flight();
                    if let Some(j) = journal {
                        j.emit(EventKind::Respond, lane as u16, 0, total_us, req.trace_id);
                    }
                    let _ = req.resp.send(Response {
                        id: req.id,
                        image,
                        queue_us,
                        compute_us,
                        batch_size: zs.len(),
                        span,
                        stages: if req.traced { stages.clone() } else { None },
                        fault: None,
                    });
                }
            }
            ExecOutcome::Err(e) => {
                metrics.record_error();
                if let Some(bs) = breakers {
                    bs[lane].record_failure(Instant::now());
                }
                for req in &live {
                    metrics.dec_in_flight();
                    if let Some(j) = journal {
                        j.emit(EventKind::Disconnect, lane as u16, 0, 0, req.trace_id);
                    }
                }
                // drop the responders: receivers observe disconnection,
                // and only THIS batch's requests are affected — the loop
                // (and the rest of the pool) keeps serving
                obs::log::error(
                    "coordinator",
                    &format!("batch execution failed: {e:#}"),
                    &[("worker", worker.to_string()), ("lane", lane.to_string())],
                );
            }
            ExecOutcome::Panic(msg) => {
                // blast-radius containment (DESIGN.md §15): the batch
                // panicked the worker mid-execute. Count it, open the
                // books with the breaker, rebuild the (possibly
                // mid-batch-corrupt) executor, then bisect: retry every
                // request of the batch individually so one poison pill
                // cannot take its batchmates down with it.
                metrics.record_worker_panic();
                if let Some(bs) = breakers {
                    bs[lane].record_failure(Instant::now());
                }
                if let Some(j) = journal {
                    j.emit(EventKind::WorkerPanic, lane as u16, 0, 0, live[0].trace_id);
                }
                obs::log::error(
                    "coordinator",
                    "batch panicked the worker; containing and retrying individually",
                    &[
                        ("worker", worker.to_string()),
                        ("lane", lane.to_string()),
                        ("batch", live.len().to_string()),
                        ("panic", msg),
                    ],
                );
                rebuild_executor(execs, lanes, lane, worker, journal);
                for req in live {
                    retry_one(req, worker, lane, execs, lanes, cfg, metrics, breakers, journal);
                }
            }
        }
    }
}

/// Outcome of one contained executor call.
enum ExecOutcome {
    Ok(Vec<Vec<f32>>),
    Err(anyhow::Error),
    Panic(String),
}

/// Run one executor call inside `catch_unwind`, drawing (at most) one
/// chaos action first — INSIDE the contained region, so an injected
/// panic exercises the real containment path, not a simulation of it.
/// `AssertUnwindSafe` is sound here: an executor that panicked is
/// discarded and rebuilt from its lane factory before it is used again
/// ([`rebuild_executor`]), so no broken invariant can be observed.
fn contained_execute(
    exec: &mut Box<dyn BatchExecutor>,
    zs: &[Vec<f32>],
    sink: Option<&mut StageSink>,
    chaos: Option<&FaultPlan>,
) -> ExecOutcome {
    let action = chaos.and_then(|p| p.next());
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
        match action {
            Some(ChaosAction::Panic) => panic!("chaos: injected worker panic"),
            Some(ChaosAction::Error) => return Err(anyhow!("chaos: injected executor error")),
            Some(ChaosAction::Slow(d)) => std::thread::sleep(d),
            None => {}
        }
        match sink {
            Some(s) => exec.execute_traced(zs, Some(s)),
            None => exec.execute(zs),
        }
    }));
    match caught {
        Ok(Ok(images)) => ExecOutcome::Ok(images),
        Ok(Err(e)) => ExecOutcome::Err(e),
        Err(payload) => ExecOutcome::Panic(panic_message(payload.as_ref())),
    }
}

/// Best-effort panic payload → short string for logs and typed faults.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    let msg = if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    };
    if msg.chars().count() > 200 {
        msg.chars().take(200).collect()
    } else {
        msg
    }
}

/// Rebuild one lane's executor after a panic (the old one may hold
/// arbitrary mid-batch state) and journal the respawn. Best-effort: a
/// factory failure keeps the old executor and logs — the worker must
/// stay up either way.
fn rebuild_executor(
    execs: &mut [Box<dyn BatchExecutor>],
    lanes: &[ModelLane],
    lane: usize,
    worker: usize,
    journal: Option<&Journal>,
) {
    match (lanes[lane].factory)(worker) {
        Ok(e) => {
            execs[lane] = e;
            if let Some(j) = journal {
                j.emit(EventKind::WorkerRespawn, lane as u16, 0, 0, 0);
            }
        }
        Err(e) => obs::log::error(
            "coordinator",
            &format!("executor rebuild failed: {e:#}"),
            &[("worker", worker.to_string()), ("lane", lane.to_string())],
        ),
    }
}

/// The bisect step of panic containment: run ONE request of a panicked
/// batch by itself — no chaos draw (recovery must be deterministic), no
/// stage sink. Success responds normally (`batch_size` 1); an executor
/// error keeps the legacy disconnect semantics; a SECOND panic marks
/// the request a poison pill — it is quarantined with a typed
/// [`Fault`] response (`Metrics.quarantined`) and the executor is
/// rebuilt again, so the lane keeps serving everyone else.
fn retry_one(
    req: Request,
    worker: usize,
    lane: usize,
    execs: &mut [Box<dyn BatchExecutor>],
    lanes: &[ModelLane],
    cfg: &ServerConfig,
    metrics: &Metrics,
    breakers: Option<&[Breaker]>,
    journal: Option<&Journal>,
) {
    let t0 = Instant::now();
    let outcome = contained_execute(&mut execs[lane], std::slice::from_ref(&req.z), None, None);
    match outcome {
        ExecOutcome::Ok(mut images) => {
            let compute_us = t0.elapsed().as_micros() as u64;
            if let Some(bs) = breakers {
                bs[lane].record_success();
            }
            metrics.record_batch(worker, lane, 1, compute_us, compute_us);
            let total_us = req.submitted.elapsed().as_micros() as u64;
            let queue_us = total_us.saturating_sub(compute_us);
            metrics.record_request_latency(total_us, queue_us, compute_us);
            metrics.dec_in_flight();
            if let Some(j) = journal {
                j.emit(EventKind::ComputeEnd, lane as u16, 1, compute_us, 0);
                j.emit(EventKind::Respond, lane as u16, 0, total_us, req.trace_id);
            }
            let span = if cfg.record_spans {
                Span {
                    trace_id: req.trace_id,
                    queue_us,
                    batch_form_us: 0,
                    compute_us,
                    respond_us: 0,
                }
            } else {
                Span::default()
            };
            let _ = req.resp.send(Response {
                id: req.id,
                image: images.pop().unwrap_or_default(),
                queue_us,
                compute_us,
                batch_size: 1,
                span,
                stages: None,
                fault: None,
            });
        }
        ExecOutcome::Err(e) => {
            // the batch panicked AND the individual retry errored: the
            // request still gets a TYPED response (its batch's panic is
            // the root cause the client should see), never a silent drop
            metrics.record_error();
            metrics.dec_in_flight();
            if let Some(bs) = breakers {
                bs[lane].record_failure(Instant::now());
            }
            obs::log::error(
                "coordinator",
                &format!("containment retry failed: {e:#}"),
                &[("worker", worker.to_string()), ("lane", lane.to_string())],
            );
            let total_us = req.submitted.elapsed().as_micros() as u64;
            if let Some(j) = journal {
                j.emit(EventKind::Respond, lane as u16, 0, total_us, req.trace_id);
            }
            let _ = req.resp.send(Response {
                id: req.id,
                image: Vec::new(),
                queue_us: total_us,
                compute_us: 0,
                batch_size: 1,
                span: Span::default(),
                stages: None,
                fault: Some(Fault {
                    kind: FaultKind::WorkerPanic,
                    msg: format!("batch panicked; retry failed: {e:#}"),
                }),
            });
        }
        ExecOutcome::Panic(msg) => {
            metrics.record_worker_panic();
            metrics.record_quarantined();
            if let Some(bs) = breakers {
                bs[lane].record_failure(Instant::now());
            }
            if let Some(j) = journal {
                j.emit(EventKind::WorkerPanic, lane as u16, 1, 0, req.trace_id);
            }
            obs::log::warn(
                "coordinator",
                "request quarantined after panicking the worker twice",
                &[
                    ("worker", worker.to_string()),
                    ("lane", lane.to_string()),
                    ("request", req.id.to_string()),
                    ("panic", msg.clone()),
                ],
            );
            rebuild_executor(execs, lanes, lane, worker, journal);
            let total_us = req.submitted.elapsed().as_micros() as u64;
            metrics.dec_in_flight();
            if let Some(j) = journal {
                j.emit(EventKind::Respond, lane as u16, 0, total_us, req.trace_id);
            }
            let _ = req.resp.send(Response {
                id: req.id,
                image: Vec::new(),
                queue_us: total_us,
                compute_us: 0,
                batch_size: 1,
                span: Span::default(),
                stages: None,
                fault: Some(Fault {
                    kind: FaultKind::Quarantined,
                    msg,
                }),
            });
        }
    }
}
