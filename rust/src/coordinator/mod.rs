//! L3 coordinator: the serving stack around the AOT-compiled generator.
//!
//! A bounded request queue feeds a dispatcher thread that owns the compute
//! backend (PJRT handles are not `Send`, so the backend is constructed
//! inside the thread from a `Send` factory). The dispatcher implements
//! *dynamic batching*: it blocks for the first request, then drains the
//! queue up to `max_batch` or until `batch_timeout` elapses, packs the
//! latents, runs one executable call, and fans responses back out.
//! Backpressure is the bounded queue: `submit` fails fast when full.
//!
//! Invariants (tested in rust/tests/coordinator.rs):
//! * every submitted request gets exactly one response (no drop/dup);
//! * responses carry the request's own image (order-independent identity);
//! * queue length never exceeds `queue_cap`;
//! * batch sizes never exceed `max_batch`.

pub mod executor;
pub mod metrics;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

pub use executor::{BatchExecutor, NativeExecutor, PjrtExecutor};
pub use metrics::{Metrics, MetricsSnapshot};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// maximum requests packed into one executable call
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch after the first arrival
    pub batch_timeout: Duration,
    /// bounded queue depth (backpressure limit)
    pub queue_cap: usize,
    /// which benchmark model the *native* backend serves (any spelling
    /// [`crate::networks::by_name`] accepts: dcgan, artgan, sngan, gpgan,
    /// mde, fst) — [`Server::start_native`] compiles it into an
    /// `engine::Plan`. The PJRT backend takes an explicit artifact prefix
    /// instead (artifact families can outnumber models, e.g. `dcgan_sd` vs
    /// `dcgan_nzp`); callers should derive it from
    /// [`crate::networks::slug`], as the CLI does.
    pub model: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 64,
            model: "dcgan".to_string(),
        }
    }
}

/// A generation request: latent vector in, image out.
struct Request {
    id: u64,
    z: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub image: Vec<f32>,
    /// time spent waiting in queue + batcher
    pub queue_us: u64,
    /// executable wall time for the whole batch
    pub compute_us: u64,
    /// how many requests shared the executable call
    pub batch_size: usize,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Server {
    tx: SyncSender<Msg>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Start with a backend factory (runs inside the dispatcher thread).
    pub fn start_with<F, E>(cfg: ServerConfig, factory: F) -> Result<Server>
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: BatchExecutor,
    {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        // report backend construction success/failure synchronously
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("sd-dispatcher".into())
            .spawn(move || {
                let exec = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                dispatch_loop(rx, exec, cfg, m2);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("dispatcher died during startup"))??;
        Ok(Server {
            tx,
            next_id: AtomicU64::new(0),
            metrics,
            handle: Some(handle),
        })
    }

    /// Start the production PJRT server for a model artifact prefix.
    pub fn start_pjrt(
        cfg: ServerConfig,
        artifact_dir: std::path::PathBuf,
        prefix: String,
    ) -> Result<Server> {
        Self::start_with(cfg, move || PjrtExecutor::new(artifact_dir, &prefix))
    }

    /// Start a server over the CPU-native engine executor: the generator
    /// selected by `cfg.model` is compiled ONCE into an `engine::Plan` (SD
    /// filters pre-split and packed at plan time) and serves every batch
    /// from that plan. Works from a fresh checkout (no artifacts needed);
    /// all six benchmark networks route here.
    pub fn start_native(cfg: ServerConfig, weight_seed: u64) -> Result<Server> {
        let model = cfg.model.clone();
        Self::start_with(cfg, move || NativeExecutor::for_model(&model, weight_seed))
    }

    /// Submit a latent vector. Returns a receiver for the response, or an
    /// error immediately if the queue is full (backpressure) or closed.
    pub fn submit(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            z,
            submitted: Instant::now(),
            resp: resp_tx,
        };
        match self.tx.try_send(Msg::Req(req)) {
            Ok(()) => Ok(resp_rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("queue full (backpressure)")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }

    /// Submit, blocking while the queue is full.
    pub fn submit_blocking(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request {
                id,
                z,
                submitted: Instant::now(),
                resp: resp_tx,
            }))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(resp_rx)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn dispatch_loop<E: BatchExecutor>(
    rx: Receiver<Msg>,
    mut exec: E,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
) {
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_timeout;
        let mut shutdown = false;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => batch.push(r),
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }

        let zs: Vec<Vec<f32>> = batch.iter().map(|r| r.z.clone()).collect();
        let t0 = Instant::now();
        match exec.execute(&zs) {
            Ok(images) => {
                let compute_us = t0.elapsed().as_micros() as u64;
                metrics.record_batch(batch.len(), compute_us);
                for (req, image) in batch.into_iter().zip(images) {
                    let queue_us = req.submitted.elapsed().as_micros() as u64 - compute_us.min(
                        req.submitted.elapsed().as_micros() as u64,
                    );
                    let total_us = req.submitted.elapsed().as_micros() as u64;
                    metrics.record_latency(total_us);
                    let _ = req.resp.send(Response {
                        id: req.id,
                        image,
                        queue_us,
                        compute_us,
                        batch_size: zs.len(),
                    });
                }
            }
            Err(e) => {
                metrics.record_error();
                // drop the responders: receivers observe disconnection
                eprintln!("batch execution failed: {e:#}");
            }
        }

        if shutdown {
            return;
        }
    }
}
