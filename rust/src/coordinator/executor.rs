//! Batch executors: the interface between the coordinator (batching,
//! routing, backpressure) and the compute backend.
//!
//! Two production executors: [`PjrtExecutor`] runs the AOT-compiled DCGAN
//! generator through the PJRT runtime (requires `make artifacts`), and
//! [`NativeExecutor`] pairs a shared compiled [`Program`] from the
//! `engine` subsystem (any of the six benchmark networks, with
//! split-deconvolution filters pre-split at compile time, executing on the
//! im2col + GEMM convolution kernel) with a private
//! [`crate::engine::Scratch`] — so the full serving path works from a
//! fresh checkout and N workers serve ONE compile. Because PJRT handles are not `Send`, executors are constructed
//! *inside* each dispatcher thread via a `Send + Sync` factory closure
//! called once per worker (see [`super::Server::start_with`]); tests plug
//! in mocks.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::engine::{Plan, Program};
use crate::obs::StageSink;
use crate::runtime::Engine;

/// Runs batches of latent vectors into batches of images.
pub trait BatchExecutor {
    /// Batch sizes with a compiled executable, ascending.
    fn supported_batches(&self) -> &[usize];
    /// Latent-vector length (input 0 per request).
    fn z_len(&self) -> usize;
    /// Flattened image length per request.
    fn image_len(&self) -> usize;
    /// Execute a batch; returns one image per request, in order.
    fn execute(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// [`BatchExecutor::execute`] with an optional per-layer stage sink
    /// (DESIGN.md §12). Backends that can attribute time to engine stages
    /// override this (the native engine does); the default ignores the
    /// sink and must stay **bit-identical** to `execute` — tracing is an
    /// observation channel, never a different compute path.
    fn execute_traced(
        &mut self,
        batch: &[Vec<f32>],
        _sink: Option<&mut StageSink>,
    ) -> Result<Vec<Vec<f32>>> {
        self.execute(batch)
    }
}

/// Pick the execution batch size for `n` queued requests: the smallest
/// supported size >= n, else the largest supported (callers chunk).
pub fn plan_batch(supported: &[usize], n: usize) -> usize {
    debug_assert!(!supported.is_empty());
    for &b in supported {
        if b >= n {
            return b;
        }
    }
    *supported.last().unwrap()
}

/// Chunk `n` queued requests into per-executable calls: each chunk is
/// `(take, exec_b)` — `take` real requests run on the `exec_b`-sized
/// executable (zero-padded lanes when `take < exec_b`). The chunks
/// partition `0..n` in order with no overlap or gap, so no request ever
/// crosses a chunk boundary and none is executed twice (property-tested in
/// rust/tests/batch_packing.rs).
///
/// This is PJRT *executable granularity*, not serve-path batching policy:
/// the dispatcher forms batches with the continuous batcher
/// ([`crate::coordinator::LaneQueue::fill`] — up to `max_batch` or a fill
/// budget, whichever first) and hands the whole batch to the executor;
/// only [`PjrtExecutor`] then chunks internally because its AOT
/// executables come in fixed batch sizes. The native path runs any batch
/// length directly.
pub fn chunk_batches(supported: &[usize], n: usize) -> Vec<(usize, usize)> {
    let mut chunks = Vec::new();
    let mut cursor = 0;
    while cursor < n {
        let remaining = n - cursor;
        let b = plan_batch(supported, remaining);
        let take = remaining.min(b);
        chunks.push((take, b));
        cursor += take;
    }
    chunks
}

/// PJRT-backed executor for the DCGAN generator artifacts
/// (`dcgan_sd_b1`, `dcgan_sd_b4`, ... per the manifest).
pub struct PjrtExecutor {
    engine: Engine,
    names: Vec<(usize, String)>, // (batch, artifact name), ascending
    batches: Vec<usize>,
    z_len: usize,
    image_len: usize,
}

impl PjrtExecutor {
    /// `prefix` selects the model family, e.g. "dcgan_sd".
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, prefix: &str) -> Result<Self> {
        let mut engine = Engine::new(artifact_dir)?;
        let mut names: Vec<(usize, String)> = engine
            .manifest()
            .select(|a| a.kind == "model" && a.name.starts_with(prefix))
            .iter()
            .map(|a| (a.batch, a.name.clone()))
            .collect();
        names.sort();
        if names.is_empty() {
            bail!("no model artifacts with prefix {prefix}");
        }
        // compile all variants up front (AOT: no compile on the hot path)
        let mut z_len = 0;
        let mut image_len = 0;
        for (b, name) in &names {
            let c = engine.load(name)?;
            z_len = c.spec.inputs[0].numel() / b;
            image_len = c.spec.output.numel() / b;
        }
        let batches = names.iter().map(|(b, _)| *b).collect();
        Ok(PjrtExecutor {
            engine,
            names,
            batches,
            z_len,
            image_len,
        })
    }
}

impl BatchExecutor for PjrtExecutor {
    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn z_len(&self) -> usize {
        self.z_len
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn execute(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(batch.len());
        let mut cursor = 0;
        for (take, b) in chunk_batches(&self.batches, batch.len()) {
            let name = self
                .names
                .iter()
                .find(|(nb, _)| *nb == b)
                .map(|(_, n)| n.clone())
                .unwrap();
            // pack + zero-pad to the executable's batch size; only the
            // first `take` lanes are ever read back, so padding lanes
            // cannot leak into a response
            let mut z = vec![0.0f32; b * self.z_len];
            for (i, req) in batch[cursor..cursor + take].iter().enumerate() {
                z[i * self.z_len..(i + 1) * self.z_len].copy_from_slice(req);
            }
            let compiled = self.engine.load(&name)?;
            let flat = compiled.run(&z)?;
            for i in 0..take {
                out.push(flat[i * self.image_len..(i + 1) * self.image_len].to_vec());
            }
            cursor += take;
        }
        Ok(out)
    }
}

/// CPU-native executor: an [`engine::Plan`](Plan) (shared `Arc<Program>`
/// + private `Scratch`) for any of the six benchmark networks — SD
/// deconvolution filters pre-split and pre-packed at compile time, every
/// layer on the im2col + GEMM conv kernel
/// ([`crate::tensor::conv2d_gemm`]). The whole dynamic batch runs as ONE
/// batched tensor pass (batch packed into the N axis), so the
/// dispatcher's batching directly widens the GEMM — the serving-stack
/// payoff of the engine subsystem. The program is immutable and shared:
/// the worker pool holds one `Arc<Program>` and gives each worker its own
/// executor via [`NativeExecutor::from_program`]. Needs no artifacts;
/// weights are seeded-random (the conversion-exactness property served
/// here is weight-independent, see DESIGN.md section 6).
pub struct NativeExecutor {
    plan: Plan,
    /// advisory only — see [`BatchExecutor::supported_batches`] impl note
    batches: Vec<usize>,
}

impl NativeExecutor {
    /// Compile a program for the named benchmark network (any spelling
    /// [`crate::networks::by_name`] accepts). The program is built once
    /// here; every subsequent batch reuses it.
    pub fn for_model(model: &str, weight_seed: u64) -> Result<Self> {
        let net = crate::networks::by_name_or_err(model)?;
        let plan = Plan::from_seed(&net, crate::engine::DeconvImpl::Sd, weight_seed)?;
        Ok(Self::from_plan(plan))
    }

    /// An executor over an already-compiled (shared) program, with a fresh
    /// scratch — how the worker pool spawns N executors from ONE compile.
    pub fn from_program(program: Arc<Program>) -> Self {
        Self::from_plan(Plan::from_program(program))
    }

    fn from_plan(plan: Plan) -> Self {
        NativeExecutor {
            plan,
            batches: vec![1, 2, 4, 8, 16],
        }
    }

    /// The shared compiled program (for spawning sibling executors).
    pub fn program(&self) -> &Arc<Program> {
        self.plan.program()
    }

    /// DCGAN generator (64x64x3 output, z length 100).
    pub fn dcgan(weight_seed: u64) -> Self {
        Self::for_model("dcgan", weight_seed).expect("the DCGAN plan always compiles")
    }
}

impl BatchExecutor for NativeExecutor {
    /// Advisory: the native path has no compiled-executable granularity —
    /// [`BatchExecutor::execute`] on this executor accepts *any* batch
    /// length with no padding or chunking. This list only serves batch
    /// planners ([`plan_batch`]) that expect discrete sizes.
    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn z_len(&self) -> usize {
        self.plan.input_len()
    }

    fn image_len(&self) -> usize {
        self.plan.output_len()
    }

    fn execute(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.plan.execute_batch(batch)
    }

    /// The native engine attributes per-layer im2col/GEMM/epilogue/
    /// interleave time directly from the compiled program's steps.
    fn execute_traced(
        &mut self,
        batch: &[Vec<f32>],
        sink: Option<&mut StageSink>,
    ) -> Result<Vec<Vec<f32>>> {
        self.plan.execute_batch_traced(batch, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_executor_batch_equals_singles() {
        let mut exec = NativeExecutor::dcgan(3);
        let mut rng = crate::util::rng::Rng::new(41);
        let zs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(100)).collect();
        let batched = exec.execute(&zs).unwrap();
        assert_eq!(batched.len(), 3);
        assert_eq!(batched[0].len(), exec.image_len());
        for (i, z) in zs.iter().enumerate() {
            let single = exec.execute(std::slice::from_ref(z)).unwrap();
            let max = batched[i]
                .iter()
                .zip(&single[0])
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max < 1e-4, "request {i}: batch vs single diff {max}");
        }
    }

    #[test]
    fn native_executor_rejects_bad_latent() {
        let mut exec = NativeExecutor::dcgan(3);
        assert!(exec.execute(&[vec![0.0; 7]]).is_err());
        assert!(exec.execute(&[]).unwrap().is_empty());
    }

    #[test]
    fn plan_batch_picks_smallest_covering() {
        let s = [1, 4];
        assert_eq!(plan_batch(&s, 1), 1);
        assert_eq!(plan_batch(&s, 2), 4);
        assert_eq!(plan_batch(&s, 4), 4);
        assert_eq!(plan_batch(&s, 9), 4); // chunked by caller
    }

    #[test]
    fn chunk_batches_partitions_in_order() {
        assert_eq!(chunk_batches(&[1, 4], 9), vec![(4, 4), (4, 4), (1, 1)]);
        assert_eq!(chunk_batches(&[2], 5), vec![(2, 2), (2, 2), (1, 2)]);
        assert!(chunk_batches(&[1, 4], 0).is_empty());
    }

    #[test]
    fn sibling_executors_share_one_program() {
        let mut a = NativeExecutor::for_model("sngan", 2).unwrap();
        let mut b = NativeExecutor::from_program(a.program().clone());
        assert!(Arc::ptr_eq(a.program(), b.program()));
        let mut rng = crate::util::rng::Rng::new(6);
        let z = vec![rng.normal_vec(a.z_len())];
        assert_eq!(a.execute(&z).unwrap(), b.execute(&z).unwrap());
    }
}
