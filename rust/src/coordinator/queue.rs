//! Bounded multi-producer / multi-consumer queues (Mutex + Condvar — the
//! offline registry has no crossbeam), the spine of the worker pool.
//!
//! `std::sync::mpsc` would force one consumer (its `Receiver` is neither
//! `Sync` nor cloneable); these queues let N dispatcher workers drain one
//! shared request stream. Two shapes:
//!
//! * [`BoundedQueue`] — one FIFO lane, the original single-model spine
//!   (kept as a standalone utility with its own tests);
//! * [`LaneQueue`] — N independent FIFO lanes behind ONE lock, the
//!   multi-tenant spine: each lane is one model's admission-controlled
//!   queue (per-lane `cap`), consumers take work from *any* lane with a
//!   fair round-robin scan ([`pop_any`]) and then fill a single-lane batch
//!   with the *continuous batcher* ([`fill`]): keep popping that lane
//!   until the batch reaches `max_batch` OR an absolute deadline passes —
//!   whichever fires first. The deadline is absolute, so a trickle of
//!   stragglers can never extend the wait (property-tested in
//!   rust/tests/batch_packing.rs).
//!
//! Shared semantics both queues build the coordinator's invariants on:
//!
//! * **bounded**: at most `cap` items per lane are ever queued;
//!   [`try_push`] fails fast when full (backpressure — the front door
//!   answers this with an explicit shed response), [`push`] blocks until
//!   space frees;
//! * **close-then-drain**: [`close`] stops all pushes immediately, but
//!   consumers keep popping until every lane is empty — an item accepted
//!   before close is never dropped by the queue;
//! * **deadline pops**: [`pop_deadline`] waits for the next item only
//!   until the batch deadline.
//!
//! [`try_push`]: LaneQueue::try_push
//! [`push`]: LaneQueue::push
//! [`close`]: LaneQueue::close
//! [`pop_any`]: LaneQueue::pop_any
//! [`fill`]: LaneQueue::fill
//! [`pop_deadline`]: BoundedQueue::pop_deadline

use std::collections::VecDeque;
use std::sync::{Condvar, LockResult, Mutex, PoisonError};
use std::time::Instant;

/// Poison-recovering unwrap for lock/wait results: queue state is plain
/// data (`VecDeque`s + a bool) that is valid after ANY panic, so a
/// poisoned mutex degrades to the inner guard instead of cascading the
/// panic through every producer and consumer of the serving plane
/// (DESIGN.md §15). Works for `Mutex::lock`, `Condvar::wait`, and
/// `Condvar::wait_timeout` alike — they all return a [`LockResult`].
fn sweep<T>(r: LockResult<T>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// Why a non-blocking push was refused; the item is handed back.
pub enum PushError<T> {
    /// the queue is at capacity (backpressure — retry or reject upstream)
    Full(T),
    /// the queue was closed (server shutting down)
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
pub enum PopDeadline<T> {
    /// an item arrived before the deadline
    Item(T),
    /// the deadline passed with the queue empty
    Timeout,
    /// the queue is closed **and** fully drained — no item can ever come
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
}

/// The shared bounded queue. Producers and consumers hold it behind an
/// `Arc`; all methods take `&self`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to >= 1).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                cap: cap.max(1),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Current queue depth (racy by nature — for metrics/tests).
    pub fn len(&self) -> usize {
        sweep(self.inner.lock()).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push. On success returns the queue depth *including*
    /// the new item (the backpressure high-water metric).
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut q = sweep(self.inner.lock());
        if q.closed {
            return Err(PushError::Closed(item));
        }
        if q.items.len() >= q.cap {
            return Err(PushError::Full(item));
        }
        q.items.push_back(item);
        let depth = q.items.len();
        drop(q);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking push: waits while the queue is full. Returns the post-push
    /// depth, or hands the item back if the queue is (or gets) closed.
    pub fn push(&self, item: T) -> Result<usize, T> {
        let mut q = sweep(self.inner.lock());
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < q.cap {
                break;
            }
            q = sweep(self.not_full.wait(q));
        }
        q.items.push_back(item);
        let depth = q.items.len();
        drop(q);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking pop: waits for an item; `None` only once the queue is
    /// closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut q = sweep(self.inner.lock());
        loop {
            if let Some(item) = q.items.pop_front() {
                drop(q);
                self.not_full.notify_one();
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = sweep(self.not_empty.wait(q));
        }
    }

    /// Pop, waiting at most until `deadline`. Distinguishes "nothing yet"
    /// ([`PopDeadline::Timeout`]) from "nothing ever again"
    /// ([`PopDeadline::Closed`]) so the batcher can stop filling early on
    /// shutdown.
    pub fn pop_deadline(&self, deadline: Instant) -> PopDeadline<T> {
        let mut q = sweep(self.inner.lock());
        loop {
            if let Some(item) = q.items.pop_front() {
                drop(q);
                self.not_full.notify_one();
                return PopDeadline::Item(item);
            }
            if q.closed {
                return PopDeadline::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopDeadline::Timeout;
            }
            q = sweep(self.not_empty.wait_timeout(q, deadline - now)).0;
        }
    }

    /// Close the queue: every pending and future push fails, every blocked
    /// producer/consumer wakes. Items already queued stay poppable
    /// (close-then-drain).
    pub fn close(&self) {
        sweep(self.inner.lock()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

struct LanesInner<T> {
    lanes: Vec<VecDeque<T>>,
    cap: usize, // per lane
    closed: bool,
    rr: usize, // round-robin scan start for pop_any fairness
}

/// N independent bounded FIFO lanes behind one lock — the multi-tenant
/// request spine (lane = model). See the module docs for semantics.
pub struct LaneQueue<T> {
    inner: Mutex<LanesInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    lane_count: usize,
}

impl<T> LaneQueue<T> {
    /// `lanes` FIFO lanes (clamped to >= 1) of at most `cap` items each
    /// (clamped to >= 1).
    pub fn new(lanes: usize, cap: usize) -> LaneQueue<T> {
        let lanes = lanes.max(1);
        LaneQueue {
            inner: Mutex::new(LanesInner {
                lanes: (0..lanes).map(|_| VecDeque::new()).collect(),
                cap: cap.max(1),
                closed: false,
                rr: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            lane_count: lanes,
        }
    }

    /// Number of lanes (fixed at construction).
    pub fn lane_count(&self) -> usize {
        self.lane_count
    }

    /// Current depth of one lane (racy by nature — for metrics/tests).
    pub fn len(&self, lane: usize) -> usize {
        sweep(self.inner.lock()).lanes[lane].len()
    }

    /// Total queued items across all lanes.
    pub fn total_len(&self) -> usize {
        sweep(self.inner.lock()).lanes.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Non-blocking push into `lane`. On success returns the LANE depth
    /// *including* the new item (the per-model backpressure high-water
    /// metric). `Full` is the admission-control signal: the caller owes
    /// the client an explicit shed answer, never a silent drop.
    pub fn try_push(&self, lane: usize, item: T) -> Result<usize, PushError<T>> {
        let mut q = sweep(self.inner.lock());
        if q.closed {
            return Err(PushError::Closed(item));
        }
        if q.lanes[lane].len() >= q.cap {
            return Err(PushError::Full(item));
        }
        q.lanes[lane].push_back(item);
        let depth = q.lanes[lane].len();
        drop(q);
        // notify_all: waiters are heterogeneous (pop_any vs single-lane
        // fill), so a single notify could wake a consumer that cannot use
        // this item while the one that could keeps sleeping
        self.not_empty.notify_all();
        Ok(depth)
    }

    /// Blocking push into `lane`: waits while that lane is full. Returns
    /// the post-push lane depth, or hands the item back if the queue is
    /// (or gets) closed.
    pub fn push(&self, lane: usize, item: T) -> Result<usize, T> {
        let mut q = sweep(self.inner.lock());
        loop {
            if q.closed {
                return Err(item);
            }
            if q.lanes[lane].len() < q.cap {
                break;
            }
            q = sweep(self.not_full.wait(q));
        }
        q.lanes[lane].push_back(item);
        let depth = q.lanes[lane].len();
        drop(q);
        self.not_empty.notify_all();
        Ok(depth)
    }

    /// Blocking pop from ANY lane, round-robin fair: the scan starts one
    /// past the last lane served, so a busy lane cannot starve the others.
    /// `None` only once the queue is closed **and** every lane is drained.
    pub fn pop_any(&self) -> Option<(usize, T)> {
        let mut q = sweep(self.inner.lock());
        loop {
            let n = q.lanes.len();
            let start = q.rr;
            for k in 0..n {
                let lane = (start + k) % n;
                if let Some(item) = q.lanes[lane].pop_front() {
                    q.rr = (lane + 1) % n;
                    drop(q);
                    self.not_full.notify_all();
                    return Some((lane, item));
                }
            }
            if q.closed {
                return None;
            }
            q = sweep(self.not_empty.wait(q));
        }
    }

    /// Pop from one lane, waiting at most until `deadline`.
    fn pop_lane_deadline(&self, lane: usize, deadline: Instant) -> PopDeadline<T> {
        let mut q = sweep(self.inner.lock());
        loop {
            if let Some(item) = q.lanes[lane].pop_front() {
                drop(q);
                self.not_full.notify_all();
                return PopDeadline::Item(item);
            }
            if q.closed {
                return PopDeadline::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopDeadline::Timeout;
            }
            q = sweep(self.not_empty.wait_timeout(q, deadline - now)).0;
        }
    }

    /// The continuous batcher: starting from whatever `batch` already
    /// holds, keep popping `lane` until the batch reaches `max_batch`
    /// items OR the absolute `deadline` passes — whichever fires first.
    /// Items already queued are drained under ONE lock acquisition
    /// **before the clock is consulted at all**, so a zero or
    /// already-elapsed budget still dispatches everything immediately
    /// available (never an empty return while requests sit queued, never
    /// a block); the deadline only bounds the wait for items that have
    /// not arrived yet, and because it is absolute a straggler trickle
    /// cannot extend it. Returns the number of items appended. Properties
    /// (never exceeds `max_batch`, budget honored within tolerance,
    /// per-producer FIFO preserved, straggler non-starvation, elapsed
    /// budget drains without waiting) are locked down in
    /// rust/tests/batch_packing.rs.
    pub fn fill(
        &self,
        lane: usize,
        batch: &mut Vec<T>,
        max_batch: usize,
        deadline: Instant,
    ) -> usize {
        let mut appended = 0;
        // fast path: everything already queued, one lock, no clock read
        {
            let mut q = sweep(self.inner.lock());
            while batch.len() < max_batch {
                match q.lanes[lane].pop_front() {
                    Some(item) => {
                        batch.push(item);
                        appended += 1;
                    }
                    None => break,
                }
            }
        }
        if appended > 0 {
            self.not_full.notify_all();
        }
        // slow path: wait out whatever budget remains for stragglers
        while batch.len() < max_batch {
            match self.pop_lane_deadline(lane, deadline) {
                PopDeadline::Item(item) => {
                    batch.push(item);
                    appended += 1;
                }
                PopDeadline::Timeout | PopDeadline::Closed => break,
            }
        }
        appended
    }

    /// Close the queue: every pending and future push fails, every blocked
    /// producer/consumer wakes. Items already queued stay poppable
    /// (close-then-drain).
    pub fn close(&self) {
        sweep(self.inner.lock()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod lane_tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn lanes_are_independent_fifos() {
        let q: LaneQueue<u32> = LaneQueue::new(2, 4);
        assert_eq!(q.lane_count(), 2);
        q.try_push(0, 10).ok().unwrap();
        q.try_push(1, 20).ok().unwrap();
        q.try_push(0, 11).ok().unwrap();
        assert_eq!(q.len(0), 2);
        assert_eq!(q.len(1), 1);
        assert_eq!(q.total_len(), 3);
        // round-robin: lane 0 first, then lane 1, then back to lane 0
        assert_eq!(q.pop_any(), Some((0, 10)));
        assert_eq!(q.pop_any(), Some((1, 20)));
        assert_eq!(q.pop_any(), Some((0, 11)));
    }

    #[test]
    fn per_lane_cap_is_independent() {
        let q: LaneQueue<u32> = LaneQueue::new(2, 1);
        q.try_push(0, 1).ok().unwrap();
        match q.try_push(0, 2) {
            Err(PushError::Full(v)) => assert_eq!(v, 2),
            _ => panic!("lane 0 must be full"),
        }
        // lane 1 still has room: admission control is per model
        assert_eq!(q.try_push(1, 3).ok(), Some(1));
    }

    #[test]
    fn close_then_drain_across_lanes() {
        let q: LaneQueue<u32> = LaneQueue::new(2, 4);
        q.try_push(0, 1).ok().unwrap();
        q.try_push(1, 2).ok().unwrap();
        q.close();
        match q.try_push(0, 3) {
            Err(PushError::Closed(v)) => assert_eq!(v, 3),
            _ => panic!("expected Closed"),
        }
        assert_eq!(q.pop_any(), Some((0, 1)));
        assert_eq!(q.pop_any(), Some((1, 2)));
        assert_eq!(q.pop_any(), None);
    }

    #[test]
    fn fill_takes_queued_items_without_waiting() {
        let q: LaneQueue<u32> = LaneQueue::new(1, 16);
        for i in 0..6 {
            q.try_push(0, i).ok().unwrap();
        }
        let (_, first) = q.pop_any().unwrap();
        let mut batch = vec![first];
        // items are already queued: a deadline in the past must not stop
        // the batcher from taking them
        let appended = q.fill(0, &mut batch, 4, Instant::now());
        assert_eq!(appended, 3);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(q.total_len(), 2);
    }

    #[test]
    fn fill_respects_deadline_on_empty_lane() {
        let q: LaneQueue<u32> = LaneQueue::new(1, 4);
        q.try_push(0, 7).ok().unwrap();
        let (_, first) = q.pop_any().unwrap();
        let mut batch = vec![first];
        let t0 = Instant::now();
        let appended = q.fill(0, &mut batch, 8, t0 + Duration::from_millis(30));
        assert_eq!(appended, 0);
        assert!(t0.elapsed() >= Duration::from_millis(25), "returned before the deadline");
        assert_eq!(batch, vec![7]);
    }

    #[test]
    fn blocking_push_wakes_on_pop_any() {
        let q = Arc::new(LaneQueue::new(1, 1));
        q.try_push(0, 1).ok().unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(0, 2));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_any(), Some((0, 1)));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(q.pop_any(), Some((0, 2)));
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Arc<LaneQueue<u32>> = Arc::new(LaneQueue::new(3, 2));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop_any());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_depth() {
        let q = BoundedQueue::new(4);
        assert!(q.is_empty());
        assert_eq!(q.try_push(1).ok(), Some(1));
        assert_eq!(q.try_push(2).ok(), Some(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_full_hands_item_back() {
        let q = BoundedQueue::new(1);
        assert!(q.try_push(7).is_ok());
        match q.try_push(8) {
            Err(PushError::Full(v)) => assert_eq!(v, 8),
            _ => panic!("expected Full"),
        }
    }

    #[test]
    fn close_then_drain() {
        let q = BoundedQueue::new(4);
        q.try_push(1).ok().unwrap();
        q.try_push(2).ok().unwrap();
        q.close();
        match q.try_push(3) {
            Err(PushError::Closed(v)) => assert_eq!(v, 3),
            _ => panic!("expected Closed"),
        }
        // items accepted before close are still served, in order
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_deadline_times_out_then_delivers() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let deadline = Instant::now() + Duration::from_millis(10);
        match q.pop_deadline(deadline) {
            PopDeadline::Timeout => {}
            _ => panic!("expected Timeout"),
        }
        q.try_push(5).ok().unwrap();
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            PopDeadline::Item(v) => assert_eq!(v, 5),
            _ => panic!("expected Item"),
        }
        q.close();
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            PopDeadline::Closed => {}
            _ => panic!("expected Closed"),
        }
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).ok().unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_wakes_blocked_producer_and_consumer() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.try_push(1).ok().unwrap();
        let qp = q.clone();
        let producer = std::thread::spawn(move || qp.push(2));
        let qc: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let qc2 = qc.clone();
        let consumer = std::thread::spawn(move || qc2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        qc.close();
        // blocked producer hands its item back; blocked consumer sees None
        assert_eq!(producer.join().unwrap().err(), Some(2));
        assert_eq!(consumer.join().unwrap(), None);
    }
}
