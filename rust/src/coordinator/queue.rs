//! A bounded multi-producer / multi-consumer queue (Mutex + Condvar — the
//! offline registry has no crossbeam), the spine of the worker pool.
//!
//! `std::sync::mpsc` would force one consumer (its `Receiver` is neither
//! `Sync` nor cloneable); this queue lets N dispatcher workers drain one
//! shared request stream. Semantics the coordinator builds its invariants
//! on:
//!
//! * **bounded**: at most `cap` items are ever queued; [`try_push`] fails
//!   fast when full (backpressure), [`push`] blocks until space frees;
//! * **close-then-drain**: [`close`] stops all pushes immediately, but
//!   consumers keep popping until the queue is empty — an item accepted
//!   before close is never dropped by the queue;
//! * **deadline pops**: [`pop_deadline`] is the dynamic batcher's fill
//!   primitive — wait for the next item only until the batch deadline.
//!
//! [`try_push`]: BoundedQueue::try_push
//! [`push`]: BoundedQueue::push
//! [`close`]: BoundedQueue::close
//! [`pop_deadline`]: BoundedQueue::pop_deadline

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a non-blocking push was refused; the item is handed back.
pub enum PushError<T> {
    /// the queue is at capacity (backpressure — retry or reject upstream)
    Full(T),
    /// the queue was closed (server shutting down)
    Closed(T),
}

/// Outcome of a deadline-bounded pop.
pub enum PopDeadline<T> {
    /// an item arrived before the deadline
    Item(T),
    /// the deadline passed with the queue empty
    Timeout,
    /// the queue is closed **and** fully drained — no item can ever come
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    cap: usize,
    closed: bool,
}

/// The shared bounded queue. Producers and consumers hold it behind an
/// `Arc`; all methods take `&self`.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to >= 1).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                cap: cap.max(1),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Current queue depth (racy by nature — for metrics/tests).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push. On success returns the queue depth *including*
    /// the new item (the backpressure high-water metric).
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return Err(PushError::Closed(item));
        }
        if q.items.len() >= q.cap {
            return Err(PushError::Full(item));
        }
        q.items.push_back(item);
        let depth = q.items.len();
        drop(q);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking push: waits while the queue is full. Returns the post-push
    /// depth, or hands the item back if the queue is (or gets) closed.
    pub fn push(&self, item: T) -> Result<usize, T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if q.closed {
                return Err(item);
            }
            if q.items.len() < q.cap {
                break;
            }
            q = self.not_full.wait(q).unwrap();
        }
        q.items.push_back(item);
        let depth = q.items.len();
        drop(q);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Blocking pop: waits for an item; `None` only once the queue is
    /// closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                drop(q);
                self.not_full.notify_one();
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
    }

    /// Pop, waiting at most until `deadline`. Distinguishes "nothing yet"
    /// ([`PopDeadline::Timeout`]) from "nothing ever again"
    /// ([`PopDeadline::Closed`]) so the batcher can stop filling early on
    /// shutdown.
    pub fn pop_deadline(&self, deadline: Instant) -> PopDeadline<T> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                drop(q);
                self.not_full.notify_one();
                return PopDeadline::Item(item);
            }
            if q.closed {
                return PopDeadline::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopDeadline::Timeout;
            }
            q = self.not_empty.wait_timeout(q, deadline - now).unwrap().0;
        }
    }

    /// Close the queue: every pending and future push fails, every blocked
    /// producer/consumer wakes. Items already queued stay poppable
    /// (close-then-drain).
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_depth() {
        let q = BoundedQueue::new(4);
        assert!(q.is_empty());
        assert_eq!(q.try_push(1).ok(), Some(1));
        assert_eq!(q.try_push(2).ok(), Some(2));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_full_hands_item_back() {
        let q = BoundedQueue::new(1);
        assert!(q.try_push(7).is_ok());
        match q.try_push(8) {
            Err(PushError::Full(v)) => assert_eq!(v, 8),
            _ => panic!("expected Full"),
        }
    }

    #[test]
    fn close_then_drain() {
        let q = BoundedQueue::new(4);
        q.try_push(1).ok().unwrap();
        q.try_push(2).ok().unwrap();
        q.close();
        match q.try_push(3) {
            Err(PushError::Closed(v)) => assert_eq!(v, 3),
            _ => panic!("expected Closed"),
        }
        // items accepted before close are still served, in order
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_deadline_times_out_then_delivers() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let deadline = Instant::now() + Duration::from_millis(10);
        match q.pop_deadline(deadline) {
            PopDeadline::Timeout => {}
            _ => panic!("expected Timeout"),
        }
        q.try_push(5).ok().unwrap();
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            PopDeadline::Item(v) => assert_eq!(v, 5),
            _ => panic!("expected Item"),
        }
        q.close();
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            PopDeadline::Closed => {}
            _ => panic!("expected Closed"),
        }
    }

    #[test]
    fn blocking_push_wakes_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).ok().unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(h.join().unwrap().is_ok());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_wakes_blocked_producer_and_consumer() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        q.try_push(1).ok().unwrap();
        let qp = q.clone();
        let producer = std::thread::spawn(move || qp.push(2));
        let qc: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let qc2 = qc.clone();
        let consumer = std::thread::spawn(move || qc2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        qc.close();
        // blocked producer hands its item back; blocked consumer sees None
        assert_eq!(producer.join().unwrap().err(), Some(2));
        assert_eq!(consumer.join().unwrap(), None);
    }
}
