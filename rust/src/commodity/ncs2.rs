//! Intel Neural Compute Stick 2 efficiency model, calibrated to the paper's
//! Tables 7 and 8, plus its *native deconvolution* path (NCS2 has dedicated
//! hardware support; the paper still measures SD 1.10x faster on average —
//! Figure 17).

use super::{interp, EfficiencyModel};
use crate::nn::NetworkSpec;

pub struct Ncs2;

/// Paper Table 7 (feature-map sweep at k=3): side -> normalized GMACPS.
const FMAP: &[(f64, f64)] = &[
    (8.0, 1.0),
    (16.0, 4.55),
    (32.0, 10.70),
    (64.0, 14.71),
    (128.0, 15.45),
];

/// Paper Table 8 (filter sweep at fmap=128): k -> normalized GMACPS.
const FILTER: &[(f64, f64)] = &[(2.0, 1.0), (3.0, 2.14), (4.0, 3.64), (5.0, 5.22)];

impl EfficiencyModel for Ncs2 {
    fn fmap_factor(&self, side: usize) -> f64 {
        interp(FMAP, side as f64)
    }

    fn filter_factor(&self, k: usize) -> f64 {
        interp(FILTER, (k as f64).max(1.0)).max(0.4)
    }

    fn base_gmacps(&self) -> f64 {
        // NCS2 ~1 TOPS effective on its VPU; normalized anchor at (128, k3).
        90.0
    }

    fn nzp_derate(&self) -> f64 {
        // NCS2's steep feature-map efficiency curve (Table 7: 1x -> 15.45x)
        // punishes SD's input-resolution convolutions harder than the Edge
        // TPU's; the measured 1.67x average (Fig 17) implies a stronger
        // inflation cost on the NZP side. Calibrated to that average.
        0.40
    }
}

/// Native deconvolution on the NCS2's dedicated hardware path.
///
/// Modeled as the original deconvolution MACs executed at the device's
/// efficiency for the layer's *input* geometry with the full filter, times a
/// native-path overhead factor: the vendor engine internally performs the
/// overlap-add scatter, which leaves it behind the SD formulation despite
/// executing fewer MACs (the paper measures SD/native = 1.10x on average).
/// The 3.4 factor is this model's single calibration constant: it absorbs
/// the scatter-accumulate's poor utilization of the VPU's dense conv engine.
pub fn native_deconv_time_s(net: &NetworkSpec) -> f64 {
    let m = Ncs2;
    net.deconv_layers()
        .map(|l| {
            let fmap = ((l.in_h + l.in_w) / 2).max(1);
            m.time_s(l.macs(), fmap, l.k) * 3.4
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::commodity::{nzp_time_s, sd_time_s};
    use crate::networks;

    #[test]
    fn table_anchor_values() {
        let t = Ncs2;
        assert!((t.fmap_factor(32) - 10.70).abs() < 1e-9);
        assert!((t.filter_factor(4) - 3.64).abs() < 1e-9);
    }

    #[test]
    fn fig17_ordering_nzp_native_sd() {
        // paper: SD 1.67x over NZP, 1.10x over native (averages)
        let t = Ncs2;
        let mut sd_vs_nzp = Vec::new();
        let mut sd_vs_native = Vec::new();
        for net in networks::all() {
            let nzp = nzp_time_s(&t, &net);
            let sd = sd_time_s(&t, &net, 8.0);
            let native = native_deconv_time_s(&net);
            sd_vs_nzp.push(nzp / sd);
            sd_vs_native.push(native / sd);
        }
        let a = crate::util::geomean(&sd_vs_nzp);
        let b = crate::util::geomean(&sd_vs_native);
        assert!(a > 1.2 && a < 2.6, "sd/nzp {a}");
        assert!(b > 0.9 && b < 1.8, "sd/native {b}");
        // orderings hold: SD fastest on average, native second, NZP last
        assert!(a > b, "nzp should be slower than native on average");
    }
}

#[cfg(test)]
mod dbg_tests {
    use super::*;
    use crate::commodity::{nzp_time_s, sd_time_s};
    use crate::networks;

    #[test]
    fn print_native_breakdown() {
        let t = Ncs2;
        for net in networks::all() {
            let nzp = nzp_time_s(&t, &net);
            let sd = sd_time_s(&t, &net, 8.0);
            let nat = native_deconv_time_s(&net);
            println!(
                "{:8} nzp {:.3}ms sd {:.3}ms native {:.3}ms  sd/nzp {:.2} native/sd {:.2}",
                net.name, nzp * 1e3, sd * 1e3, nat * 1e3, nzp / sd, nat / sd
            );
        }
    }
}
