//! Edge TPU efficiency model, calibrated to the paper's measured Tables 5
//! and 6 (normalized GMACPS vs feature-map size and filter size; 256 input
//! channels / 128 output channels probe layers). The Edge TPU has no native
//! deconvolution, so the paper compares NZP vs SD on it (Figure 15).

use super::{interp, EfficiencyModel};

pub struct EdgeTpu;

/// Paper Table 6 (feature-map sweep at k=3): side -> normalized GMACPS.
const FMAP: &[(f64, f64)] = &[
    (8.0, 1.0),
    (16.0, 1.32),
    (32.0, 1.76),
    (64.0, 1.88),
    (128.0, 1.98),
];

/// Paper Table 5 (filter sweep at fmap=128): k -> normalized GMACPS.
const FILTER: &[(f64, f64)] = &[(2.0, 1.0), (3.0, 2.24), (4.0, 3.80), (5.0, 5.72)];

impl EfficiencyModel for EdgeTpu {
    fn fmap_factor(&self, side: usize) -> f64 {
        interp(FMAP, side as f64)
    }

    fn filter_factor(&self, k: usize) -> f64 {
        // k=1 extrapolates below the table's k=2 anchor
        interp(FILTER, (k as f64).max(1.0)).max(0.4)
    }

    fn base_gmacps(&self) -> f64 {
        // Edge TPU peak 4 TOPS int8 == 2000 GMACPS; conv at fmap 128 / k3
        // reaches a modest fraction on the probe layer (the paper's tables
        // are normalized; the absolute anchor cancels in every figure).
        180.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::commodity::{nzp_time_s, sd_time_s};
    use crate::networks;

    #[test]
    fn table_anchor_values() {
        let t = EdgeTpu;
        assert!((t.fmap_factor(8) - 1.0).abs() < 1e-9);
        assert!((t.fmap_factor(128) - 1.98).abs() < 1e-9);
        assert!((t.filter_factor(5) - 5.72).abs() < 1e-9);
    }

    #[test]
    fn normalization_point() {
        let t = EdgeTpu;
        assert!((t.gmacps(128, 3) - t.base_gmacps()).abs() < 1e-6);
    }

    #[test]
    fn bigger_is_more_efficient() {
        let t = EdgeTpu;
        assert!(t.gmacps(128, 5) > t.gmacps(128, 3));
        assert!(t.gmacps(64, 3) > t.gmacps(8, 3));
    }

    #[test]
    fn fig15_sd_speedup_band() {
        // paper: SD 1.51x over NZP on average, max 1.65x (FST)
        let t = EdgeTpu;
        let mut speedups = Vec::new();
        for net in networks::all() {
            let nzp = nzp_time_s(&t, &net);
            let sd = sd_time_s(&t, &net, 8.0);
            speedups.push(nzp / sd);
        }
        let avg = crate::util::geomean(&speedups);
        assert!(avg > 1.2 && avg < 2.4, "avg speedup {avg}");
        // every benchmark must still favor SD
        assert!(speedups.iter().all(|s| *s > 1.0), "{speedups:?}");
    }
}
