//! Host-CPU experiment (paper Figure 16): run the per-layer NZP and SD
//! artifacts through the PJRT runtime and compare *measured wall-clock*.
//! This is the one commodity experiment that is a real measurement rather
//! than a calibrated model: both implementations execute through the same
//! AOT-compiled Pallas convolution kernel on this machine's CPU.

use anyhow::Result;

use crate::runtime::{read_bin, Engine};
use crate::util::time_it;

/// Measured times for one network's deconv layers.
#[derive(Clone, Debug)]
pub struct HostRow {
    pub network: String,
    pub nzp_s: f64,
    pub sd_s: f64,
}

impl HostRow {
    pub fn speedup(&self) -> f64 {
        self.nzp_s / self.sd_s
    }
}

/// Time every `layer_*` artifact pair and aggregate per network.
/// `iters` controls timing repetitions per layer.
pub fn measure_fig16(engine: &mut Engine, iters: usize) -> Result<Vec<HostRow>> {
    let nets: Vec<String> = {
        let mut v: Vec<String> = engine
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.kind == "layer")
            .map(|a| a.network.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    };

    let mut rows = Vec::new();
    for net in nets {
        let mut nzp_s = 0.0;
        let mut sd_s = 0.0;
        let names: Vec<(String, String)> = engine
            .manifest()
            .select(|a| a.kind == "layer" && a.network == net)
            .iter()
            .map(|a| (a.name.clone(), a.impl_.clone()))
            .collect();
        for (name, impl_) in names {
            let t = time_layer(engine, &name, iters)?;
            match impl_.as_str() {
                "nzp" => nzp_s += t,
                "sd" => sd_s += t,
                _ => {}
            }
        }
        rows.push(HostRow {
            network: net,
            nzp_s,
            sd_s,
        });
    }
    Ok(rows)
}

/// Wall-clock one artifact (input from its golden bin; excludes compile).
pub fn time_layer(engine: &mut Engine, name: &str, iters: usize) -> Result<f64> {
    let compiled = engine.load(name)?;
    let input = read_bin(&compiled.spec.inputs[0].bin)?;
    // warm-up
    let _ = compiled.run(&input)?;
    Ok(time_it(iters, || {
        let _ = compiled.run(&input).expect("layer execution failed");
    }))
}

pub fn print_fig16(rows: &[HostRow]) {
    println!("Figure 16: host-CPU deconv layers, measured wall-clock (normalized to NZP = 1.0)");
    let mut speedups = Vec::new();
    for r in rows {
        println!(
            "{:<10} NZP={:.2}ms SD={:.2}ms  SD speedup {:.2}x",
            r.network,
            r.nzp_s * 1e3,
            r.sd_s * 1e3,
            r.speedup()
        );
        speedups.push(r.speedup());
    }
    println!("average speedup {:.2}x", crate::util::geomean(&speedups));
}
