//! Analytical performance models of the commodity neural-network processors
//! the paper evaluates in Section 5.3 (we have no physical Edge TPU / NCS2 —
//! see DESIGN.md section 6 for the substitution argument).
//!
//! Both chips exhibit strongly size-dependent computational efficiency: the
//! paper measures GMACPS versus feature-map size (Tables 5/7) and filter
//! size (Tables 6/8) and explains the entire SD-vs-NZP speedup gap between
//! "MAC-count prediction" and "measured" with those curves. The models here
//! are those curves, so the benches reproduce Figures 15 and 17 and the
//! degradation analysis.

pub mod edge_tpu;
pub mod host;
pub mod ncs2;

use crate::nn::{LayerSpec, NetworkSpec};
use crate::quant::sd_pack_shape;

/// A device's efficiency model: GMACPS as a function of (square) feature-map
/// side and filter side, factorized as base * f(fmap) * g(filter), which is
/// how the paper's Tables 5-8 are normalized.
pub trait EfficiencyModel {
    /// normalized efficiency vs feature-map side (Table 5 / 7 column)
    fn fmap_factor(&self, side: usize) -> f64;
    /// normalized efficiency vs filter side (Table 6 / 8 column)
    fn filter_factor(&self, k: usize) -> f64;
    /// absolute GMACPS at the normalization point (fmap 128, k 3)
    fn base_gmacps(&self) -> f64;

    /// device-specific NZP activation-inflation derate (see
    /// [`NZP_INFLATION_DERATE`]); calibrated per device to the paper's
    /// measured Figure 15 / 17 averages.
    fn nzp_derate(&self) -> f64 {
        NZP_INFLATION_DERATE
    }

    fn gmacps(&self, fmap_side: usize, k: usize) -> f64 {
        // tables normalize fmap at k=3 and filter at fmap=128
        self.base_gmacps() * self.fmap_factor(fmap_side) / self.fmap_factor(128)
            * self.filter_factor(k)
            / self.filter_factor(3)
    }

    /// Seconds to run `macs` MACs at the given geometry.
    fn time_s(&self, macs: u64, fmap_side: usize, k: usize) -> f64 {
        macs as f64 / (self.gmacps(fmap_side, k) * 1e9)
    }
}

/// Piecewise-linear interpolation over (x, factor) anchor points.
pub(crate) fn interp(points: &[(f64, f64)], x: f64) -> f64 {
    if x <= points[0].0 {
        return points[0].1;
    }
    for w in points.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        if x <= x1 {
            return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
        }
    }
    points.last().unwrap().1
}

/// Activation-inflation derate applied to NZP's dense convolution.
///
/// The paper's efficiency tables (5-8) alone would predict NZP ~on par with
/// SD (bigger kernels are *more* efficient per MAC on both devices), yet the
/// paper *measures* SD 1.51x / 1.67x faster. The residual is the cost of the
/// s^2-inflated activation working set that NZP streams through the device
/// (bandwidth + on-chip tiling pressure), which the k/fmap probe sweeps do
/// not expose. This constant calibrates that effect; the ablation bench
/// (`cargo bench fig15_17_commodity`) also reports the derate=1.0
/// tables-only prediction to make the modeling assumption visible.
pub const NZP_INFLATION_DERATE: f64 = 0.55;

/// Time for a network's deconv layers under NZP on a modeled device.
/// NZP runs one dense conv per layer at the output resolution with the
/// original filter size, derated by the inflated activation working set.
pub fn nzp_time_s<M: EfficiencyModel>(m: &M, net: &NetworkSpec) -> f64 {
    nzp_time_s_derated(m, net, m.nzp_derate())
}

/// NZP time with an explicit derate (1.0 = tables-only ablation).
pub fn nzp_time_s_derated<M: EfficiencyModel>(m: &M, net: &NetworkSpec, derate: f64) -> f64 {
    net.deconv_layers()
        .map(|l| {
            let fmap = ((l.out_h() + l.out_w()) / 2).max(1);
            m.time_s(l.nzp_macs(), fmap, l.k) / derate
        })
        .sum()
}

/// Time for a network's deconv layers under SD: s^2 convolutions with the
/// small K_T filter at roughly input resolution, plus the host-side output
/// reorganization (per the paper's measurement protocol: "we only take the
/// split deconvolution computing time and the data reorganization time").
///
/// The filter geometry (sub-filter side, per-split conv output, MAC count)
/// comes from [`sd_pack_shape`] — the **actual packed sub-filter shapes**
/// the quantized engine executes, read off a real `split_filters` packing —
/// rather than re-deriving the `SdGeometry` closed forms here. The devices
/// these models describe run int8, so the packed (quantized) geometry is
/// the ground truth.
pub fn sd_time_s<M: EfficiencyModel>(m: &M, net: &NetworkSpec, host_reorg_gbps: f64) -> f64 {
    net.deconv_layers()
        .map(|l| {
            let pack = sd_pack_shape(l);
            let conv_side = ((pack.conv_h + pack.conv_w) / 2).max(1);
            let compute = m.time_s(pack.table_macs(l), conv_side, pack.k_t);
            // reorganization: one pass over the output bytes on the host
            let out_bytes = (l.out_h() * l.out_w() * l.out_c) as f64;
            compute + out_bytes / (host_reorg_gbps * 1e9)
        })
        .sum()
}

/// Per-layer times of one deconv layer (used by reports for breakdowns).
/// SD geometry routed through [`sd_pack_shape`] like [`sd_time_s`].
pub fn layer_times_s<M: EfficiencyModel>(
    m: &M,
    l: &LayerSpec,
    host_reorg_gbps: f64,
) -> (f64, f64) {
    let fmap = ((l.out_h() + l.out_w()) / 2).max(1);
    let nzp = m.time_s(l.nzp_macs(), fmap, l.k);
    let pack = sd_pack_shape(l);
    let conv_side = ((pack.conv_h + pack.conv_w) / 2).max(1);
    let out_bytes = (l.out_h() * l.out_w() * l.out_c) as f64;
    let sd =
        m.time_s(pack.table_macs(l), conv_side, pack.k_t) + out_bytes / (host_reorg_gbps * 1e9);
    (nzp, sd)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_endpoints_and_middle() {
        let pts = [(2.0, 1.0), (4.0, 3.0)];
        assert_eq!(interp(&pts, 1.0), 1.0);
        assert_eq!(interp(&pts, 5.0), 3.0);
        assert!((interp(&pts, 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sd_times_follow_the_packed_filter_geometry() {
        // the SD estimate must be exactly what the packed sub-filter
        // shapes imply (one probe layer per SD case: expansion and
        // divisible), with the MAC count read off the packing
        let t = super::edge_tpu::EdgeTpu;
        for l in [
            LayerSpec::deconv("d", 8, 8, 256, 128, 5, 2, 2, 1),
            LayerSpec::deconv("d", 4, 4, 512, 256, 4, 2, 1, 0),
        ] {
            let pack = sd_pack_shape(&l);
            assert_eq!(pack.table_macs(&l), l.sd_macs());
            let (_, sd) = layer_times_s(&t, &l, 8.0);
            let conv_side = ((pack.conv_h + pack.conv_w) / 2).max(1);
            let want = t.time_s(pack.table_macs(&l), conv_side, pack.k_t)
                + (l.out_h() * l.out_w() * l.out_c) as f64 / (8.0 * 1e9);
            assert!((sd - want).abs() <= want * 1e-12, "sd {sd} want {want}");
        }
    }
}
